"""Virtual-clock tests of the continuous-batching serving engine.

Every scheduling assertion here runs under `repro.serve.VirtualClock`:
time moves only when the test advances it, so flush-on-timeout, the
starvation bound and bucket choices are exact, reproducible claims — no
wall-clock sleeps anywhere in this file.  The closing 8-device payload
proves the engine realizes the paper's batch amortization for real: a
coalesced batch of B requests costs ONE B-batch's 2K|E| exchange rounds.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _subproc import run_payload

from repro.core import graph, wavelets
from repro.dist import GraphOperator
from repro.serve import (PendingError, RequestFailed, ServeEngine,
                         VirtualClock, WallClock, burst_arrivals,
                         poisson_arrivals, replay_virtual)

MAX_WAIT = 0.005


@pytest.fixture(scope="module")
def op48():
    g, _ = graph.connected_sensor_graph(jax.random.PRNGKey(0), n=48,
                                        theta=0.3, kappa=0.35)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                       lmax=lmax, K=6)
    return g, op


@pytest.fixture(scope="module")
def dense_plan(op48):
    _, op = op48
    return op.plan("dense")


def make_engine(plans, buckets=(1, 4, 8), max_wait=MAX_WAIT):
    clock = VirtualClock()
    eng = ServeEngine(plans, buckets=buckets, max_wait=max_wait,
                      clock=clock, sync_results=False)
    return eng, clock


def sig(g, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), (g.n_vertices,))


# ---------------------------------------------------------------------------
# Flush triggers
# ---------------------------------------------------------------------------
def test_flush_on_batch_full(op48, dense_plan):
    """A full largest bucket dispatches inline at submit — zero delay."""
    g, _ = op48
    eng, clock = make_engine(dense_plan)
    futs = [eng.submit(sig(g, i)) for i in range(8)]
    assert all(f.done() for f in futs)
    assert eng.pending_count == 0
    assert clock.now() == 0.0                      # no time passed at all
    [batch] = eng.metrics.batches
    assert (batch.bucket, batch.occupancy, batch.padding) == (8, 8, 0)
    assert all(f.response.latency == 0.0 for f in futs)


def test_flush_on_deadline(op48, dense_plan):
    """A partial group waits exactly max_wait, then pads to its bucket."""
    g, _ = op48
    eng, clock = make_engine(dense_plan)
    futs = [eng.submit(sig(g, i)) for i in range(3)]
    assert not any(f.done() for f in futs)
    with pytest.raises(PendingError):
        futs[0].result()
    clock.advance(MAX_WAIT * 0.8)                  # before the deadline:
    assert eng.poll() == 0                         # nothing is due
    assert eng.next_deadline() == pytest.approx(MAX_WAIT)
    clock.advance_to(MAX_WAIT)
    assert eng.poll() == 3
    [batch] = eng.metrics.batches
    assert (batch.bucket, batch.occupancy, batch.padding) == (4, 3, 1)
    # virtual clock: latency is exactly the deadline wait, by construction
    assert all(f.response.latency == pytest.approx(MAX_WAIT)
               for f in futs)


def test_oversized_group_chunks_then_drains(op48, dense_plan):
    """batch-full flushes take largest-bucket chunks; the remainder rides
    the deadline flush — nothing is lost, nothing is double-served."""
    g, _ = op48
    eng, clock = make_engine(dense_plan)
    futs = [eng.submit(sig(g, i)) for i in range(11)]
    assert sum(f.done() for f in futs) == 8        # one full chunk of 8
    assert eng.pending_count == 3
    eng.run_until_idle()
    assert all(f.done() for f in futs)
    assert [(b.bucket, b.occupancy) for b in eng.metrics.batches] == \
        [(8, 8), (4, 3)]
    s = eng.metrics.summary()
    assert s["served_exactly_once"] and s["n_served"] == 11


def test_burst_rides_one_bucket(op48, dense_plan):
    """A simultaneous burst of exactly bucket size coalesces into ONE
    dispatch per burst (the loadgen's adversarial case)."""
    g, _ = op48
    eng, _ = make_engine(dense_plan, buckets=(1, 8))
    events = burst_arrivals(n_bursts=3, burst_size=8, period=0.1, seed=5,
                            mix=[(1.0, "apply", None, {})])
    replay_virtual(eng, events, n=g.n_vertices)
    assert [b.occupancy for b in eng.metrics.batches] == [8, 8, 8]
    assert eng.metrics.summary()["padding_waste"] == 0.0


# ---------------------------------------------------------------------------
# Fairness / starvation bound
# ---------------------------------------------------------------------------
def test_starvation_bound_and_fifo(op48, dense_plan):
    """No admitted request queues longer than max_wait before dispatch,
    and batches of one key take requests strictly in arrival order."""
    g, _ = op48
    eng, _ = make_engine(dense_plan)
    events = poisson_arrivals(rate=700.0, n_requests=60, seed=11)
    futs = replay_virtual(eng, events, n=g.n_vertices)
    assert eng.metrics.summary()["served_exactly_once"]
    by_key = {}
    for f in futs.values():
        r = f.response
        assert r.queue_delay <= MAX_WAIT + 1e-12   # the starvation bound
        by_key.setdefault(r.key, []).append(r)
    for rs in by_key.values():
        rs.sort(key=lambda r: (r.t_dispatch, r.id))
        # arrival order == dispatch order within a key (FIFO): a request
        # never overtakes an older compatible one into an earlier batch
        dispatch_ts = [r.t_dispatch for r in sorted(rs, key=lambda r: r.id)]
        assert dispatch_ts == sorted(dispatch_ts)


def test_due_groups_flush_oldest_first(op48, dense_plan):
    """When several keys are due in one poll, the key with the oldest
    waiting request dispatches first (FIFO fairness across keys)."""
    g, _ = op48
    eng, clock = make_engine(dense_plan)
    f_solve = eng.submit(sig(g, 0), kind="solve", method="jacobi", tau=0.5)
    clock.advance(0.001)
    f_apply = eng.submit(sig(g, 1))
    clock.advance(MAX_WAIT)                        # both now due
    eng.poll()
    assert f_solve.done() and f_apply.done()
    assert [b.key.kind for b in eng.metrics.batches] == ["solve", "apply"]


# ---------------------------------------------------------------------------
# Compatibility-key isolation
# ---------------------------------------------------------------------------
def test_compat_key_isolation(op48, dense_plan):
    """A jacobi solve never rides a chebyshev (or apply) batch: every
    dispatched batch is homogeneous in (kind, method, n_iters, tau)."""
    g, _ = op48
    eng, _ = make_engine(dense_plan)
    specs = [
        dict(kind="apply"),
        dict(kind="apply_gram"),
        dict(kind="solve", method="jacobi", tau=0.5, n_iters=4),
        dict(kind="solve", method="jacobi", tau=0.25, n_iters=4),
        dict(kind="solve", method="jacobi", tau=0.5, n_iters=6),
        dict(kind="solve", method="chebyshev", tau=0.5, n_iters=4),
    ]
    futs = []
    for i in range(24):
        futs.append((i % len(specs),
                     eng.submit(sig(g, i), **specs[i % len(specs)])))
    eng.run_until_idle()
    assert eng.metrics.summary()["served_exactly_once"]
    # six distinct keys -> six isolated groups, none co-batched
    keys = {b.key for b in eng.metrics.batches}
    assert len(keys) == len(specs)
    for spec_idx, f in futs:
        r = f.response
        want = specs[spec_idx]
        assert r.key.kind == want["kind"]
        assert r.key.method == want.get("method")
        if "tau" in want:
            assert r.key.tau == want["tau"]
        if "n_iters" in want:
            assert r.key.order == want["n_iters"]


def test_multi_operator_routing(op48):
    """Two registered operators: requests land on the plan they named and
    never co-batch across operators."""
    g, op = op48
    op_wide = GraphOperator(P=op.P, multipliers=op.multipliers,
                            lmax=op.lmax, K=12)
    plans = {"k6": op.plan("dense"), "k12": op_wide.plan("dense")}
    eng, _ = make_engine(plans)
    f = sig(g, 42)
    fut6 = eng.submit(f, op="k6")
    fut12 = eng.submit(f, op="k12")
    eng.run_until_idle()
    assert {b.key.op for b in eng.metrics.batches} == {"k6", "k12"}
    np.testing.assert_array_equal(
        np.asarray(fut6.result()),
        np.asarray(plans["k6"].compiled("apply")(f[None])[0]))
    np.testing.assert_array_equal(
        np.asarray(fut12.result()),
        np.asarray(plans["k12"].compiled("apply")(f[None])[0]))
    assert not np.allclose(np.asarray(fut6.result()),
                           np.asarray(fut12.result()))


# ---------------------------------------------------------------------------
# End-to-end correctness: served == direct, bitwise on the same bucket
# ---------------------------------------------------------------------------
def test_served_apply_bitwise_equals_direct(op48, dense_plan):
    g, _ = op48
    eng, _ = make_engine(dense_plan, buckets=(8,))
    signals = [sig(g, 100 + i) for i in range(8)]
    futs = [eng.submit(s) for s in signals]
    direct = dense_plan.compiled("apply")(jnp.stack(signals))
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.asarray(direct[i]))


def test_served_solve_bitwise_equals_direct(op48, dense_plan):
    g, _ = op48
    eng, _ = make_engine(dense_plan, buckets=(4,))
    signals = [sig(g, 200 + i) for i in range(4)]
    futs = [eng.submit(s, kind="solve", method="jacobi", tau=0.5,
                       n_iters=6) for s in signals]
    direct = dense_plan.compiled_solve("jacobi", tau=0.5, n_iters=6)(
        jnp.stack(signals))
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.asarray(direct[i]))


def test_adjoint_requests_roundtrip(op48, dense_plan):
    g, op = op48
    eng, _ = make_engine(dense_plan, buckets=(2,))
    a = jax.random.normal(jax.random.PRNGKey(7), (2, op.eta, g.n_vertices))
    futs = [eng.submit(a[0], kind="apply_adjoint"),
            eng.submit(a[1], kind="apply_adjoint")]
    direct = dense_plan.compiled("apply_adjoint")(a)
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.asarray(direct[i]))


# ---------------------------------------------------------------------------
# Admission validation + driver contracts
# ---------------------------------------------------------------------------
def test_admission_rejects_malformed(op48, dense_plan):
    g, _ = op48
    eng, _ = make_engine(dense_plan)
    f = sig(g, 0)
    with pytest.raises(ValueError, match="unknown kind"):
        eng.submit(f, kind="nope")
    with pytest.raises(ValueError, match="requires method"):
        eng.submit(f, kind="solve")
    with pytest.raises(ValueError, match="no method"):
        eng.submit(f, method="jacobi")
    with pytest.raises(ValueError, match="history"):
        eng.submit(f, kind="solve", method="jacobi", tau=0.5,
                   history=True)
    with pytest.raises(ValueError, match="batch axis"):
        eng.submit(jnp.stack([f, f]))              # engine owns the batch
    with pytest.raises(ValueError, match="plan expects"):
        eng.submit(f[:-1])
    with pytest.raises(KeyError, match="unknown operator"):
        eng.submit(f, op="nope")
    assert eng.pending_count == 0                  # nothing was admitted


def test_run_until_idle_needs_virtual_clock(dense_plan):
    eng = ServeEngine(dense_plan, clock=WallClock())
    with pytest.raises(TypeError, match="advance_to"):
        eng.run_until_idle()


def test_summary_schema(op48, dense_plan):
    g, _ = op48
    eng, _ = make_engine(dense_plan)
    replay_virtual(eng, poisson_arrivals(rate=900.0, n_requests=30,
                                         seed=2), n=g.n_vertices)
    s = eng.metrics.summary()
    assert s["n_submitted"] == s["n_served"] == 30
    assert s["served_exactly_once"]
    assert np.isfinite(s["latency_ms"]["p99"])
    assert s["latency_ms"]["p50"] <= s["latency_ms"]["p99"]
    assert s["queue_delay_ms"]["max"] <= MAX_WAIT * 1e3 + 1e-9
    assert s["signals_per_sec"] > 0
    assert s["mean_batch_occupancy"] >= 1.0
    assert 0.0 <= s["padding_waste"] < 1.0


# ---------------------------------------------------------------------------
# Hardening: dispatch-failure containment, deadlines, bounded queue, retry
# ---------------------------------------------------------------------------
def test_dispatch_failure_fails_only_that_batch(op48, dense_plan,
                                                monkeypatch):
    """A poisoned compiled callable fails exactly its batch: every rider
    gets a ``dispatch:`` error Response (no exception out of submit/poll,
    no stranded futures) and the engine keeps serving later batches —
    the regression test for the flush-failure hazard."""
    g, _ = op48
    eng, _ = make_engine(dense_plan, buckets=(1, 4))
    orig = eng._callable
    armed = {"on": True}

    def poisoned(key, group):
        if armed["on"]:
            def bad(batch):
                raise RuntimeError("poisoned kernel")
            return bad
        return orig(key, group)

    monkeypatch.setattr(eng, "_callable", poisoned)
    bad_futs = [eng.submit(sig(g, i)) for i in range(4)]  # full bucket
    for fut in bad_futs:                   # dispatched inline, all failed
        assert fut.done() and not fut.response.ok
        assert fut.response.error.startswith("dispatch: RuntimeError")
        assert fut.response.value is None
        with pytest.raises(RequestFailed, match="poisoned"):
            fut.result()
    armed["on"] = False                    # engine must still be alive
    good_futs = [eng.submit(sig(g, i + 10)) for i in range(4)]
    for i, fut in enumerate(good_futs):
        want = np.asarray(dense_plan.apply(sig(g, i + 10)))
        np.testing.assert_allclose(np.asarray(fut.result()), want,
                                   rtol=1e-5, atol=1e-5)
    s = eng.metrics.summary()
    assert s["n_failed"] == 4 and s["n_served"] == 4
    assert s["served_exactly_once"] and eng.pending_count == 0


def test_deadline_expires_queued_request(op48, dense_plan):
    """A request whose deadline passes before dispatch completes with an
    ``expired:`` error Response instead of waiting forever."""
    g, _ = op48
    eng, clock = make_engine(dense_plan, buckets=(4,), max_wait=0.05)
    doomed = eng.submit(sig(g, 0), deadline=0.002)
    alive = eng.submit(sig(g, 1))
    clock.advance(0.003)
    eng.poll()                             # sweep: past the deadline
    assert doomed.done() and doomed.response.error.startswith("expired:")
    assert not alive.done()
    eng.run_until_idle()                   # the survivor still serves
    np.testing.assert_allclose(np.asarray(alive.result()),
                               np.asarray(dense_plan.apply(sig(g, 1))),
                               rtol=1e-5, atol=1e-5)
    s = eng.metrics.summary()
    assert s["n_expired"] == 1 and s["n_served"] == 1
    assert s["served_exactly_once"]
    with pytest.raises(ValueError, match="deadline"):
        eng.submit(sig(g, 2), deadline=-0.1)


def test_deadline_expiry_at_dispatch_time(op48, dense_plan):
    """Expiry is also enforced when the batch is popped: a request whose
    deadline passed rides no batch even if the sweep never saw it."""
    g, _ = op48
    eng, clock = make_engine(dense_plan, buckets=(2,), max_wait=0.05)
    doomed = eng.submit(sig(g, 0), deadline=0.001)
    clock.advance(0.002)
    live = eng.submit(sig(g, 1))           # fills the bucket -> dispatch
    eng.run_until_idle()
    assert doomed.response.error.startswith("expired:")
    assert live.response.ok
    assert eng.metrics.summary()["served_exactly_once"]


def test_bounded_queue_rejects_at_admission(op48, dense_plan):
    """`max_queue_depth` refuses requests at admission with a
    ``rejected:`` error Response — rejected requests never enter the
    exactly-once set and the queue never exceeds the bound."""
    g, _ = op48
    eng, _ = make_engine(dense_plan, buckets=(8,), max_wait=0.05)
    eng.max_queue_depth = 2
    admitted = [eng.submit(sig(g, i)) for i in range(2)]
    bounced = eng.submit(sig(g, 9))
    assert bounced.done() and bounced.response.rejected
    assert "max_queue_depth=2" in bounced.response.error
    assert eng.pending_count == 2
    eng.run_until_idle()
    assert all(f.response.ok for f in admitted)
    s = eng.metrics.summary()
    assert s["n_rejected"] == 1 and s["n_served"] == 2
    assert s["n_submitted"] == 2           # rejections are not admissions
    assert s["served_exactly_once"]
    with pytest.raises(ValueError, match="max_queue_depth"):
        ServeEngine(dense_plan, clock=VirtualClock(), max_queue_depth=0)


def test_retry_policy_absorbs_queue_full_windows(op48, dense_plan):
    """The loadgen retry/backoff hook resubmits rejected requests after
    the queue drains: every event index ends with a served future."""
    from repro.serve import RetryPolicy
    g, _ = op48
    clock = VirtualClock()
    eng = ServeEngine(dense_plan, buckets=(1, 4), max_wait=0.001,
                      clock=clock, sync_results=False, max_queue_depth=2)
    events = burst_arrivals(n_bursts=2, burst_size=6, period=0.05, seed=0,
                            mix=((1.0, "apply", None, {}),))
    futs = replay_virtual(eng, events, n=g.n_vertices,
                          retry=RetryPolicy(max_retries=4, backoff=0.002))
    assert set(futs) == set(range(len(events)))
    assert all(f.response.ok for f in futs.values())
    s = eng.metrics.summary()
    assert s["n_rejected"] > 0             # the bound really bit
    assert s["n_served"] == len(events)
    assert s["served_exactly_once"]
    assert RetryPolicy().delay(2) == pytest.approx(0.002 * 4.0)


# ---------------------------------------------------------------------------
# 8 devices: the engine actually realizes the 2K|E| batch amortization
# ---------------------------------------------------------------------------
PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph, wavelets
from repro.dist import GraphOperator
from repro.dist.commstats import measure
from repro.serve import ServeEngine, VirtualClock

B, K = 32, 10
key = jax.random.PRNGKey(1)
g, key = graph.connected_sensor_graph(key, n=600, theta=0.07, kappa=0.07)
gs, _ = graph.spatial_sort(g)
lmax = gs.lambda_max_bound()
op = GraphOperator(P=gs.laplacian(),
                   multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                   lmax=lmax, K=K)
mesh = jax.make_mesh((8,), ("graph",),
                     axis_types=(jax.sharding.AxisType.Auto,))
plan = op.plan("pallas_halo", mesh=mesh)
eng = ServeEngine(plan, buckets=(1, B), max_wait=0.005,
                  clock=VirtualClock(), sync_results=False)
signals = [jax.random.normal(jax.random.PRNGKey(100 + i), (g.n_vertices,))
           for i in range(B)]
futs = [eng.submit(s) for s in signals]
assert all(f.done() for f in futs), "full bucket must dispatch inline"
[batch] = eng.metrics.batches
assert (batch.bucket, batch.occupancy) == (B, B), batch

# served rows == the SAME memoized compiled callable, bitwise
direct = plan.compiled("apply")(jnp.stack(signals))
for i, f in enumerate(futs):
    assert np.array_equal(np.asarray(f.result()), np.asarray(direct[i])), i

# the traffic the engine's one dispatch generates: trace the (B, N)
# signature it launched -> K exchange rounds total, i.e. the coalesced
# batch of B requests costs ONE B-batch's 2K|E| messages, not B of them
st_b = measure(plan.apply,
               jax.ShapeDtypeStruct((B, g.n_vertices), np.float32),
               n_shards=8, batch=B)
st_1 = measure(plan.apply,
               jax.ShapeDtypeStruct((g.n_vertices,), np.float32),
               n_shards=8)
assert st_b.exchange_rounds == K, st_b.exchange_rounds
assert st_b.paper_messages(g.n_edges) == 2 * K * g.n_edges
assert st_b.paper_messages(g.n_edges) == st_1.paper_messages(g.n_edges)
assert st_b.paper_messages_per_signal(g.n_edges) \
    == 2 * K * g.n_edges / B
print("SERVE COALESCE OK")
"""


def test_engine_coalesces_to_one_batch_traffic_8shards():
    """8 forced host devices: B requests served by the engine ride one
    (B, N) launch whose measured traffic is exactly one B-batch's 2K|E|
    — the batch-amortization claim, realized by serving."""
    out = run_payload(PAYLOAD, n_devices=8)
    assert "SERVE COALESCE OK" in out
